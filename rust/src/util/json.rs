//! Minimal JSON parser + serializer (the vendor set has no serde).
//!
//! Supports the full JSON grammar minus exotic number formats; good enough
//! for `artifacts/manifest.json`, run configs and report emission. Numbers
//! are kept as f64 (manifest values are small ints and floats).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Builder: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: JSON encodes astral chars as two \u escapes.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if &self.b[self.pos..self.pos.min(self.b.len()).max(self.pos)]
                                    == b""
                                    && self.b[self.pos..].len() < 6
                                {
                                    return Err(self.err("lone surrogate"));
                                }
                                if &self.b[self.pos..self.pos + 2] != b"\\u" {
                                    return Err(self.err("lone surrogate"));
                                }
                                self.pos += 2;
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                        .map_err(|_| self.err("bad surrogate"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad surrogate"))?;
                                self.pos += 4;
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                cp
                            };
                            out.push(
                                char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"num":3,"obj":{"k":true}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }
}
