//! Declarative command-line flag parser (the vendor set has no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, defaults, and
//! generated `--help`. Used by the `lqr` binary, the examples and benches.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    boolean: bool,
}

/// A small builder-style argument parser.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    specs: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args { program: program.into(), about: about.into(), ..Default::default() }
    }

    /// Declare a flag with a default value.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            boolean: false,
        });
        self
    }

    /// Declare a required flag (no default).
    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            boolean: false,
        });
        self
    }

    /// Declare a boolean switch (`--name` sets true).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: Some("false".into()),
            boolean: true,
        });
        self
    }

    fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nFLAGS:\n", self.program, self.about);
        for spec in &self.specs {
            let d = match (&spec.default, spec.boolean) {
                (_, true) => " (switch)".to_string(),
                (Some(d), _) => format!(" (default: {d})"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse from an explicit token list. Returns Err(message) on bad input;
    /// the message for `--help` is the usage text.
    pub fn parse_from(mut self, argv: &[String]) -> Result<Parsed, String> {
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?
                    .clone();
                let value = if spec.boolean {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i).cloned().ok_or(format!("--{name} needs a value"))?
                };
                self.values.insert(name, value);
            } else {
                self.positional.push(tok.clone());
            }
            i += 1;
        }
        // defaults + required check
        for spec in &self.specs {
            if !self.values.contains_key(&spec.name) {
                match &spec.default {
                    Some(d) => {
                        self.values.insert(spec.name.clone(), d.clone());
                    }
                    None => return Err(format!("missing required --{}\n\n{}", spec.name, self.usage())),
                }
            }
        }
        Ok(Parsed { values: self.values, positional: self.positional })
    }

    /// Parse from the process arguments; exits the process on error/help.
    pub fn parse(self) -> Parsed {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&argv) {
            Ok(p) => p,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.contains("FLAGS:") { 0 } else { 2 });
            }
        }
    }
}

/// Parsed flag values with typed getters.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| panic!("undeclared flag {name}"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes")
    }

    /// Comma-separated list of usize, e.g. "8,6,4,2".
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad list")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = Args::new("t", "test")
            .flag("bits", "8", "bit width")
            .switch("verbose", "chatty")
            .parse_from(&argv(&["--bits", "4"]))
            .unwrap();
        assert_eq!(p.get_usize("bits"), 4);
        assert!(!p.get_bool("verbose"));
    }

    #[test]
    fn equals_and_switch() {
        let p = Args::new("t", "test")
            .flag("model", "a", "")
            .switch("fast", "")
            .parse_from(&argv(&["--model=vgg", "--fast", "pos1"]))
            .unwrap();
        assert_eq!(p.get("model"), "vgg");
        assert!(p.get_bool("fast"));
        assert_eq!(p.positional, vec!["pos1"]);
    }

    #[test]
    fn required_missing() {
        let e = Args::new("t", "test").required("out", "").parse_from(&argv(&[])).unwrap_err();
        assert!(e.contains("missing required --out"));
    }

    #[test]
    fn unknown_flag() {
        let e = Args::new("t", "test").parse_from(&argv(&["--nope"])).unwrap_err();
        assert!(e.contains("unknown flag"));
    }

    #[test]
    fn list_parsing() {
        let p = Args::new("t", "test")
            .flag("bits", "8,6,4,2", "")
            .parse_from(&argv(&[]))
            .unwrap();
        assert_eq!(p.get_usize_list("bits"), vec![8, 6, 4, 2]);
    }
}
