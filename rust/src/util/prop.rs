//! Tiny property-testing harness (the vendor set has no proptest).
//!
//! Deterministic: every case derives from a fixed master seed, and a failing
//! case reports its case-seed so it can be replayed exactly with
//! [`check_one`]. No shrinking — generators are kept small enough that raw
//! failures are readable.

use crate::util::rng::Rng;

/// Number of cases per property (override with LQR_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("LQR_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

/// Run `prop(rng, case_index)` for `cases` deterministic cases; panics with
/// the failing seed on error.
pub fn check_named(name: &str, master_seed: u64, cases: usize, prop: impl Fn(&mut Rng, usize)) {
    for case in 0..cases {
        let case_seed = master_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64 + 1);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case} (replay: check_one({case_seed:#x}, ..)):\n{msg}"
            );
        }
    }
}

/// Run the default number of cases.
pub fn check(name: &str, master_seed: u64, prop: impl Fn(&mut Rng, usize)) {
    check_named(name, master_seed, default_cases(), prop);
}

/// Replay a single case from its reported seed.
pub fn check_one(case_seed: u64, prop: impl Fn(&mut Rng)) {
    let mut rng = Rng::new(case_seed);
    prop(&mut rng);
}

// ---- common generators ----------------------------------------------------

/// Random tensor dims: (rows, cols) with both in [1, max].
pub fn gen_dims(rng: &mut Rng, max: usize) -> (usize, usize) {
    (rng.index(1, max + 1), rng.index(1, max + 1))
}

/// Random f32 data with occasionally-nasty distributions: normal, constant,
/// tiny-range, large-range — the cases quantization must survive.
pub fn gen_values(rng: &mut Rng, n: usize) -> Vec<f32> {
    match rng.below(4) {
        0 => rng.normal_vec(n),
        1 => {
            let c = rng.range(-5.0, 5.0);
            vec![c; n] // constant region: span == 0 edge case
        }
        2 => rng.uniform_vec(n, -1e-4, 1e-4),
        _ => rng.uniform_vec(n, -1e3, 1e3),
    }
}

/// Random bit width from the paper's set {1, 2, 4, 6, 8}.
pub fn gen_bits(rng: &mut Rng) -> usize {
    [1usize, 2, 4, 6, 8][rng.below(5) as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check_named("add-commutes", 1, 16, |rng, _| {
            let a = rng.normal() as f32;
            let b = rng.normal() as f32;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failure_with_seed() {
        check_named("always-fails", 1, 4, |_, _| panic!("boom"));
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = |seed| {
            let out = std::sync::Mutex::new(Vec::new());
            check_named("collect", seed, 8, |rng, _| out.lock().unwrap().push(rng.next_u64()));
            out.into_inner().unwrap()
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }

    #[test]
    fn generators_in_bounds() {
        check_named("gen-bounds", 3, 32, |rng, _| {
            let (m, k) = gen_dims(rng, 17);
            assert!((1..=17).contains(&m) && (1..=17).contains(&k));
            let v = gen_values(rng, m * k);
            assert_eq!(v.len(), m * k);
            assert!(v.iter().all(|x| x.is_finite()));
            assert!([1, 2, 4, 6, 8].contains(&gen_bits(rng)));
        });
    }
}
