//! Fixed-size thread pool (no rayon/tokio in the vendor set).
//!
//! Two primitives:
//! - [`ThreadPool`]: long-lived workers consuming boxed jobs from a shared
//!   queue — used by the coordinator's worker runtime.
//! - [`scope_chunks`]: data-parallel helper that splits an index range into
//!   contiguous chunks across threads — used by the fixed-point GEMMs. Runs
//!   on a lazily-initialized process-wide [`shared_pool`], so per-GEMM cost
//!   is a queue push per chunk instead of an OS thread spawn per chunk
//!   (spawn latency dominated small conv-layer GEMMs in the seed).
//!
//! The shared pool sizes itself to `available_parallelism`, overridable via
//! the `LQR_THREADS` env var (see `rust/README.md` for the full knob table).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A classic shared-queue thread pool. Jobs are executed FIFO; `join` blocks
/// until every submitted job has finished. Workers survive panicking jobs
/// (the panic is swallowed after the pending count is settled), so one bad
/// job can't wedge later submitters.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Msg::Run(job)) => {
                            // Keep the worker alive across panicking jobs;
                            // scoped callers re-raise on their own thread.
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(job),
                            );
                            let (lock, cv) = &*pending;
                            let mut p = lock.lock().unwrap();
                            *p -= 1;
                            if *p == 0 {
                                cv.notify_all();
                            }
                        }
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { tx, handles, pending }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Submit a job for asynchronous execution.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.send(Msg::Run(Box::new(job))).expect("pool shut down");
    }

    /// Block until all submitted jobs have completed.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The process-wide data-parallel pool backing [`scope_chunks`], created on
/// first use and sized to the machine — or to `LQR_THREADS` when that env
/// var is set to a positive integer (read once, at pool creation; it caps
/// every `scope_chunks` caller since the pool size bounds the claimants).
/// Never dropped (workers park on an empty queue). Coordinator worker pools
/// are separate `ThreadPool` instances, so a worker blocking in
/// `scope_chunks` cannot starve itself.
pub fn shared_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::env::var("LQR_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
        ThreadPool::new(n.max(1))
    })
}

/// Completion latch for one `scope_chunks` call: counts finished chunks and
/// keeps the first panic payload so the caller can re-raise it with its
/// original message (property-test counterexamples stay readable).
struct ScopeLatch {
    state: Mutex<(usize, Option<Box<dyn std::any::Any + Send>>)>,
    cv: Condvar,
}

impl ScopeLatch {
    fn chunk_done(&self, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut s = self.state.lock().unwrap();
        s.0 += 1;
        if s.1.is_none() {
            s.1 = panic;
        }
        self.cv.notify_all();
    }

    fn wait(&self, jobs: usize) -> Option<Box<dyn std::any::Any + Send>> {
        let mut s = self.state.lock().unwrap();
        while s.0 < jobs {
            s = self.cv.wait(s).unwrap();
        }
        s.1.take()
    }
}

/// Split `0..n` into contiguous chunks and run `f(start, end)` on the
/// shared pool, blocking until every chunk completes. `f` runs on the
/// caller thread when `threads <= 1` or the range is tiny — keeping the hot
/// path allocation-free for small work. The caller always works too (one
/// fewer queue round-trip, and progress is guaranteed even when the pool is
/// saturated by other scopes).
///
/// Long ranges split into `2 * threads` chunks claimed from a shared
/// cursor: uneven per-chunk cost (ragged M-blocks, cache effects, a busy
/// core) rebalances across the claimants instead of serializing the scope
/// on its slowest pre-assigned chunk. At most `threads` claimants run at
/// once (`threads - 1` pool workers + the caller) — the caller's thread
/// budget is a cap, not a hint.
///
/// `f` must not recursively call `scope_chunks` (the kernels never do):
/// nested scopes could occupy every worker with blocked parents.
pub fn scope_chunks(n: usize, threads: usize, f: impl Fn(usize, usize) + Sync) {
    if threads <= 1 || n < 2 * threads {
        f(0, n);
        return;
    }
    let pool = shared_pool();
    let threads = threads.min(pool.size()).max(1);
    let parts = if n >= threads * 4 { threads * 2 } else { threads };
    let chunk = n.div_ceil(parts);
    let nchunks = n.div_ceil(chunk);
    if nchunks <= 1 {
        f(0, n);
        return;
    }
    let njobs = (threads - 1).min(nchunks - 1); // pool claimants besides the caller
    if njobs == 0 {
        f(0, n);
        return;
    }

    let latch = Arc::new(ScopeLatch { state: Mutex::new((0, None)), cv: Condvar::new() });
    let cursor = Arc::new(AtomicUsize::new(0));
    let fref: &(dyn Fn(usize, usize) + Sync) = &f;
    // SAFETY: the latch wait below does not return until every submitted
    // job has run to completion (or panicked), so the borrow of `f` (and
    // everything it captures) strictly outlives the forged 'static jobs.
    let fjob: &'static (dyn Fn(usize, usize) + Sync) = unsafe { std::mem::transmute(fref) };
    for _ in 0..njobs {
        let latch = Arc::clone(&latch);
        let cursor = Arc::clone(&cursor);
        pool.execute(move || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                drain_chunks(fjob, &cursor, nchunks, chunk, n)
            }));
            latch.chunk_done(r.err());
        });
    }
    // Caller thread claims chunks too — never behind the queue.
    let r0 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        drain_chunks(fref, &cursor, nchunks, chunk, n)
    }));
    // A panicking claimant abandons its loop, but the cursor keeps handing
    // the remaining chunks to the other claimants, so the wait terminates.
    let worker_panic = latch.wait(njobs);
    if let Err(p) = r0 {
        std::panic::resume_unwind(p);
    }
    if let Some(p) = worker_panic {
        std::panic::resume_unwind(p);
    }
}

/// Claim-and-run loop shared by the pool jobs of one `scope_chunks` call.
fn drain_chunks(
    g: &(dyn Fn(usize, usize) + Sync),
    cursor: &AtomicUsize,
    nchunks: usize,
    chunk: usize,
    n: usize,
) {
    loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= nchunks {
            break;
        }
        g(i * chunk, ((i + 1) * chunk).min(n));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn join_waits_for_slow_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                thread::sleep(std::time::Duration::from_millis(20));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        pool.execute(|| panic!("job panic"));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn scope_chunks_covers_range() {
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        scope_chunks(100, 7, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scope_chunks_single_thread() {
        let sum = AtomicUsize::new(0);
        scope_chunks(10, 1, |s, e| {
            sum.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn scope_chunks_reuses_shared_pool() {
        // Back-to-back scoped calls must not leave pending work behind and
        // must keep covering their ranges exactly once (pool reuse).
        for round in 0..20 {
            let n = 64 + round;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            scope_chunks(n, 4, |s, e| {
                for i in s..e {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "round {round}");
        }
    }

    #[test]
    fn concurrent_scopes_do_not_interfere() {
        // Several threads sharing the pool at once: each scope's latch is
        // private, so completions must not cross wires.
        let handles: Vec<_> = (0..4)
            .map(|_| {
                thread::spawn(|| {
                    let hits: Vec<AtomicUsize> = (0..200).map(|_| AtomicUsize::new(0)).collect();
                    scope_chunks(200, 3, |s, e| {
                        for i in s..e {
                            hits[i].fetch_add(1, Ordering::SeqCst);
                        }
                    });
                    hits.iter().all(|h| h.load(Ordering::SeqCst) == 1)
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap());
        }
    }

    #[test]
    fn scope_chunks_propagates_worker_panic() {
        if shared_pool().size() < 2 {
            return; // single-core host: everything runs inline on the caller
        }
        let caught = std::panic::catch_unwind(|| {
            scope_chunks(100, 4, |s, _e| {
                if s > 0 {
                    panic!("chunk failure s={s}");
                }
            });
        });
        let payload = caught.expect_err("worker panic must surface to the caller");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string>".into());
        assert!(msg.contains("chunk failure"), "original payload preserved, got {msg}");
    }
}
