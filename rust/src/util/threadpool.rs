//! Fixed-size thread pool (no rayon/tokio in the vendor set).
//!
//! Two primitives:
//! - [`ThreadPool`]: long-lived workers consuming boxed jobs from a shared
//!   queue — used by the coordinator's worker runtime.
//! - [`scope_chunks`]: data-parallel helper that splits an index range into
//!   contiguous chunks across threads — used by the fixed-point GEMMs.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A classic shared-queue thread pool. Jobs are executed FIFO; `join` blocks
/// until every submitted job has finished.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Msg::Run(job)) => {
                            job();
                            let (lock, cv) = &*pending;
                            let mut p = lock.lock().unwrap();
                            *p -= 1;
                            if *p == 0 {
                                cv.notify_all();
                            }
                        }
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { tx, handles, pending }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Submit a job for asynchronous execution.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.send(Msg::Run(Box::new(job))).expect("pool shut down");
    }

    /// Block until all submitted jobs have completed.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Split `0..n` into `threads` contiguous chunks and run `f(start, end)` on
/// scoped threads. `f` runs on the caller thread when `threads <= 1` or the
/// range is tiny — keeping the hot path allocation-free for small work.
pub fn scope_chunks(n: usize, threads: usize, f: impl Fn(usize, usize) + Sync) {
    if threads <= 1 || n < 2 * threads {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(start, end));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn join_waits_for_slow_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                thread::sleep(std::time::Duration::from_millis(20));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn scope_chunks_covers_range() {
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        scope_chunks(100, 7, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scope_chunks_single_thread() {
        let sum = AtomicUsize::new(0);
        scope_chunks(10, 1, |s, e| {
            sum.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 10);
    }
}
