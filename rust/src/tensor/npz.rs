//! npy/npz reader (subset): the interchange format between build-time python
//! (`np.savez`) and the rust runtime.
//!
//! Supports the exact encoding numpy's `savez` emits — a STORED (and, for
//! `savez_compressed`, DEFLATE — rejected here) zip archive of `.npy` members
//! with v1/v2 headers — for little-endian f32/f64/i32/i64 C-order arrays.
//! Implemented from the npy-format spec + zip appnote rather than pulling a
//! zip crate so the tensor substrate stays dependency-free.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Tensor;

/// One named array from an npz archive.
#[derive(Debug, Clone)]
pub struct NpzEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: NpzData,
}

#[derive(Debug, Clone)]
pub enum NpzData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl NpzEntry {
    /// View as an f32 [`Tensor`] (i32 data is converted).
    pub fn to_tensor(&self) -> Tensor {
        match &self.data {
            NpzData::F32(v) => Tensor::new(&self.shape, v.clone()),
            NpzData::I32(v) => {
                Tensor::new(&self.shape, v.iter().map(|&x| x as f32).collect())
            }
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match &self.data {
            NpzData::I32(v) => Some(v),
            _ => None,
        }
    }
}

fn rd_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([b[at], b[at + 1]])
}

fn rd_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

/// Parse the npy header dict: "{'descr': '<f4', 'fortran_order': False, 'shape': (2, 3), }".
fn parse_npy_header(h: &str) -> Result<(String, bool, Vec<usize>)> {
    let get = |key: &str| -> Result<&str> {
        let pat = format!("'{key}':");
        let at = h.find(&pat).with_context(|| format!("npy header missing {key}"))?;
        Ok(h[at + pat.len()..].trim_start())
    };
    let descr_rest = get("descr")?;
    if !descr_rest.starts_with('\'') {
        bail!("structured npy dtypes unsupported");
    }
    let descr: String = descr_rest[1..]
        .chars()
        .take_while(|&c| c != '\'')
        .collect();
    let fortran = get("fortran_order")?.starts_with("True");
    let shape_rest = get("shape")?;
    if !shape_rest.starts_with('(') {
        bail!("bad shape in npy header");
    }
    let close = shape_rest.find(')').context("bad shape")?;
    let dims: Vec<usize> = shape_rest[1..close]
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().context("bad dim"))
        .collect::<Result<_>>()?;
    Ok((descr, fortran, dims))
}

fn parse_npy(bytes: &[u8]) -> Result<(Vec<usize>, NpzData)> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        bail!("not an npy file");
    }
    let (major, header_len, body_at) = match bytes[6] {
        1 => (1u8, rd_u16(bytes, 8) as usize, 10),
        2 => (2u8, rd_u32(bytes, 8) as usize, 12),
        v => bail!("npy version {v} unsupported"),
    };
    let _ = major;
    let header = std::str::from_utf8(&bytes[body_at..body_at + header_len])
        .context("npy header not utf8")?;
    let (descr, fortran, shape) = parse_npy_header(header)?;
    if fortran {
        bail!("fortran-order arrays unsupported");
    }
    let n: usize = shape.iter().product();
    let body = &bytes[body_at + header_len..];
    let data = match descr.as_str() {
        "<f4" => {
            if body.len() < n * 4 {
                bail!("npy body too short");
            }
            NpzData::F32(
                body[..n * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )
        }
        "<f8" => NpzData::F32(
            body[..n * 8]
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()) as f32)
                .collect(),
        ),
        "<i4" => NpzData::I32(
            body[..n * 4]
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        "<i8" => NpzData::I32(
            body[..n * 8]
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().unwrap()) as i32)
                .collect(),
        ),
        d => bail!("npy dtype {d} unsupported"),
    };
    Ok((shape, data))
}

const EOCD_SIG: u32 = 0x0605_4b50;
const CDIR_SIG: u32 = 0x0201_4b50;
const LOCAL_SIG: u32 = 0x0403_4b50;

/// Read every array from an npz archive.
pub fn read_npz(path: impl AsRef<Path>) -> Result<Vec<NpzEntry>> {
    let path = path.as_ref();
    let mut f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let size = f.metadata()?.len();
    // Find the end-of-central-directory record (no zip comment expected, but
    // scan the tail to be safe).
    let tail_len = size.min(66_000);
    f.seek(SeekFrom::End(-(tail_len as i64)))?;
    let mut tail = vec![0u8; tail_len as usize];
    f.read_exact(&mut tail)?;
    let eocd_at = (0..tail.len().saturating_sub(21))
        .rev()
        .find(|&i| rd_u32(&tail, i) == EOCD_SIG)
        .context("zip end-of-central-directory not found")?;
    let n_entries = rd_u16(&tail, eocd_at + 10) as usize;
    let cdir_off = rd_u32(&tail, eocd_at + 16) as u64;
    let cdir_size = rd_u32(&tail, eocd_at + 12) as usize;

    let mut cdir = vec![0u8; cdir_size];
    f.seek(SeekFrom::Start(cdir_off))?;
    f.read_exact(&mut cdir)?;

    let mut entries = Vec::with_capacity(n_entries);
    let mut at = 0usize;
    for _ in 0..n_entries {
        if rd_u32(&cdir, at) != CDIR_SIG {
            bail!("bad central directory entry");
        }
        let method = rd_u16(&cdir, at + 10);
        let csize = rd_u32(&cdir, at + 20) as usize;
        let name_len = rd_u16(&cdir, at + 28) as usize;
        let extra_len = rd_u16(&cdir, at + 30) as usize;
        let comment_len = rd_u16(&cdir, at + 32) as usize;
        let local_off = rd_u32(&cdir, at + 42) as u64;
        let name = String::from_utf8_lossy(&cdir[at + 46..at + 46 + name_len]).to_string();
        at += 46 + name_len + extra_len + comment_len;
        if method != 0 {
            bail!("{name}: compressed npz members unsupported (use np.savez, not savez_compressed)");
        }
        // Local header: sizes may differ (extra field), re-read lengths.
        let mut lh = [0u8; 30];
        f.seek(SeekFrom::Start(local_off))?;
        f.read_exact(&mut lh)?;
        if rd_u32(&lh, 0) != LOCAL_SIG {
            bail!("bad local header for {name}");
        }
        let lh_name = rd_u16(&lh, 26) as u64;
        let lh_extra = rd_u16(&lh, 28) as u64;
        let mut body = vec![0u8; csize];
        f.seek(SeekFrom::Start(local_off + 30 + lh_name + lh_extra))?;
        f.read_exact(&mut body)?;

        let member = name.strip_suffix(".npy").unwrap_or(&name).to_string();
        let (shape, data) = parse_npy(&body).with_context(|| format!("member {name}"))?;
        entries.push(NpzEntry { name: member, shape, data });
    }
    Ok(entries)
}

/// Member names in an npz archive (cheap: central directory only).
pub fn read_npz_names(path: impl AsRef<Path>) -> Result<Vec<String>> {
    Ok(read_npz(path)?.into_iter().map(|e| e.name).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_parse() {
        let (d, f, s) =
            parse_npy_header("{'descr': '<f4', 'fortran_order': False, 'shape': (2, 3), }")
                .unwrap();
        assert_eq!(d, "<f4");
        assert!(!f);
        assert_eq!(s, vec![2, 3]);
    }

    #[test]
    fn header_scalar_and_1d() {
        let (_, _, s) =
            parse_npy_header("{'descr': '<i4', 'fortran_order': False, 'shape': (), }").unwrap();
        assert!(s.is_empty());
        let (_, _, s) =
            parse_npy_header("{'descr': '<i4', 'fortran_order': False, 'shape': (5,), }")
                .unwrap();
        assert_eq!(s, vec![5]);
    }

    #[test]
    fn npy_roundtrip_f32() {
        // Hand-build a v1 npy: magic, ver, hlen, header, payload.
        let header = "{'descr': '<f4', 'fortran_order': False, 'shape': (2, 2), }";
        let mut h = header.to_string();
        while (10 + h.len() + 1) % 64 != 0 {
            h.push(' ');
        }
        h.push('\n');
        let mut bytes = b"\x93NUMPY\x01\x00".to_vec();
        bytes.extend((h.len() as u16).to_le_bytes());
        bytes.extend(h.as_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.5] {
            bytes.extend(v.to_le_bytes());
        }
        let (shape, data) = parse_npy(&bytes).unwrap();
        assert_eq!(shape, vec![2, 2]);
        match data {
            NpzData::F32(v) => assert_eq!(v, vec![1.0, 2.0, 3.0, 4.5]),
            _ => panic!("wrong dtype"),
        }
    }

    // Reading real numpy-written npz files is covered by the integration test
    // rust/tests/npz_interop.rs against artifacts/ produced by `make artifacts`.
}
