//! npy/npz reader + writer (subset): the interchange format between
//! build-time python (`np.savez`) and the rust runtime.
//!
//! Supports the exact encoding numpy's `savez` emits — a STORED (and, for
//! `savez_compressed`, DEFLATE — rejected here) zip archive of `.npy` members
//! with v1/v2 headers — for little-endian f32/f64/i32/i64 C-order arrays.
//! Implemented from the npy-format spec + zip appnote rather than pulling a
//! zip crate so the tensor substrate stays dependency-free.
//!
//! The read path is single-copy: the archive is read (or handed in) as one
//! byte buffer, members are located as slices of that buffer (no per-member
//! seek+read), and each array is decoded straight from its slice into its
//! typed `Vec`. [`NpzEntry::into_tensor`] then *moves* that storage into the
//! [`Tensor`] — model cold-start never duplicates weight bytes.
//!
//! The read path *validates* as it decodes: non-finite floats, zero-sized
//! dimensions, and body-length mismatches surface as typed [`NpzError`]s,
//! so a corrupt weight archive fails the load instead of crashing (or
//! silently poisoning) the serving plane.

use std::fs::File;
use std::io::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Tensor;

/// Typed validation failure for array payloads: a corrupt or hostile model
/// file must degrade to a load error at the npz boundary, never to a NaN
/// propagating through the serving plane or a mis-sized weight tensor. The
/// vendored `anyhow` subset has no downcasting, so callers that care match
/// on the formatted message; `?` converts into `anyhow::Error` elsewhere.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NpzError {
    /// A float array holds NaN or ±Inf (index of the first offender).
    /// f64 members are checked *after* the f32 narrowing, so an f64 value
    /// that overflows f32 range is caught here too.
    NonFinite { index: usize },
    /// The header shape contains a zero-sized dimension — no weight or
    /// activation tensor is legitimately empty, and downstream layers
    /// assume non-empty storage.
    ZeroDim { shape: Vec<usize> },
    /// Body byte length does not exactly match `shape × dtype size` — a
    /// truncated or padded member means the offsets (or the file) are
    /// corrupt; decoding a prefix would silently mis-load weights.
    LengthMismatch { expected: usize, got: usize },
}

impl std::fmt::Display for NpzError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NpzError::NonFinite { index } => {
                write!(f, "non-finite value (NaN/Inf) at element {index}")
            }
            NpzError::ZeroDim { shape } => {
                write!(f, "zero-sized dimension in shape {shape:?}")
            }
            NpzError::LengthMismatch { expected, got } => {
                write!(f, "body length mismatch: expected {expected} bytes, got {got}")
            }
        }
    }
}

impl std::error::Error for NpzError {}

/// One named array from an npz archive.
#[derive(Debug, Clone)]
pub struct NpzEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: NpzData,
}

#[derive(Debug, Clone)]
pub enum NpzData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl NpzEntry {
    /// View as an f32 [`Tensor`] (i32 data is converted). Clones the
    /// storage; loaders that are done with the entry should prefer
    /// [`NpzEntry::into_tensor`].
    pub fn to_tensor(&self) -> Tensor {
        match &self.data {
            NpzData::F32(v) => Tensor::new(&self.shape, v.clone()),
            NpzData::I32(v) => {
                Tensor::new(&self.shape, v.iter().map(|&x| x as f32).collect())
            }
        }
    }

    /// Consume the entry into an f32 [`Tensor`] without copying: f32 storage
    /// moves, i32 storage is converted through `Vec`'s in-place
    /// `into_iter().map().collect()` (same element size/alignment, so the
    /// allocation is reused).
    pub fn into_tensor(self) -> Tensor {
        let NpzEntry { shape, data, .. } = self;
        match data {
            NpzData::F32(v) => Tensor::new(&shape, v),
            NpzData::I32(v) => {
                Tensor::new(&shape, v.into_iter().map(|x| x as f32).collect())
            }
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match &self.data {
            NpzData::I32(v) => Some(v),
            _ => None,
        }
    }
}

fn rd_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([b[at], b[at + 1]])
}

fn rd_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

/// Parse the npy header dict: "{'descr': '<f4', 'fortran_order': False, 'shape': (2, 3), }".
fn parse_npy_header(h: &str) -> Result<(String, bool, Vec<usize>)> {
    let get = |key: &str| -> Result<&str> {
        let pat = format!("'{key}':");
        let at = h.find(&pat).with_context(|| format!("npy header missing {key}"))?;
        Ok(h[at + pat.len()..].trim_start())
    };
    let descr_rest = get("descr")?;
    if !descr_rest.starts_with('\'') {
        bail!("structured npy dtypes unsupported");
    }
    let descr: String = descr_rest[1..]
        .chars()
        .take_while(|&c| c != '\'')
        .collect();
    let fortran = get("fortran_order")?.starts_with("True");
    let shape_rest = get("shape")?;
    if !shape_rest.starts_with('(') {
        bail!("bad shape in npy header");
    }
    let close = shape_rest.find(')').context("bad shape")?;
    let dims: Vec<usize> = shape_rest[1..close]
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().context("bad dim"))
        .collect::<Result<_>>()?;
    Ok((descr, fortran, dims))
}

fn parse_npy(bytes: &[u8]) -> Result<(Vec<usize>, NpzData)> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        bail!("not an npy file");
    }
    let (major, header_len, body_at) = match bytes[6] {
        1 => (1u8, rd_u16(bytes, 8) as usize, 10),
        2 => (2u8, rd_u32(bytes, 8) as usize, 12),
        v => bail!("npy version {v} unsupported"),
    };
    let _ = major;
    if bytes.len() < body_at + header_len {
        bail!("npy header truncated");
    }
    let header = std::str::from_utf8(&bytes[body_at..body_at + header_len])
        .context("npy header not utf8")?;
    let (descr, fortran, shape) = parse_npy_header(header)?;
    if fortran {
        bail!("fortran-order arrays unsupported");
    }
    if shape.iter().any(|&d| d == 0) {
        return Err(NpzError::ZeroDim { shape }.into());
    }
    let n: usize = shape.iter().product();
    let body = &bytes[body_at + header_len..];
    let data = match descr.as_str() {
        "<f4" => {
            let body = body_exact(body, n, 4)?;
            let v: Vec<f32> = body
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            ensure_finite(&v)?;
            NpzData::F32(v)
        }
        "<f8" => {
            let body = body_exact(body, n, 8)?;
            let v: Vec<f32> = body
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()) as f32)
                .collect();
            ensure_finite(&v)?;
            NpzData::F32(v)
        }
        "<i4" => {
            let body = body_exact(body, n, 4)?;
            NpzData::I32(
                body.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )
        }
        "<i8" => {
            let body = body_exact(body, n, 8)?;
            NpzData::I32(
                body.chunks_exact(8)
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()) as i32)
                    .collect(),
            )
        }
        d => bail!("npy dtype {d} unsupported"),
    };
    Ok((shape, data))
}

/// The body must hold *exactly* `n × elem` bytes (the zip member slice has
/// an exact csize, and npy bodies carry no padding). `saturating_mul` keeps
/// an overflowing hostile shape on the error path instead of wrapping into
/// a small "expected" value that could match.
fn body_exact(body: &[u8], n: usize, elem: usize) -> std::result::Result<&[u8], NpzError> {
    let expected = n.saturating_mul(elem);
    if body.len() != expected {
        return Err(NpzError::LengthMismatch { expected, got: body.len() });
    }
    Ok(body)
}

fn ensure_finite(v: &[f32]) -> std::result::Result<(), NpzError> {
    match v.iter().position(|x| !x.is_finite()) {
        Some(index) => Err(NpzError::NonFinite { index }),
        None => Ok(()),
    }
}

const EOCD_SIG: u32 = 0x0605_4b50;
const CDIR_SIG: u32 = 0x0201_4b50;
const LOCAL_SIG: u32 = 0x0403_4b50;

/// Locate the central directory in an in-memory archive: returns
/// `(entry_count, cdir_offset)`.
fn find_central_dir(bytes: &[u8]) -> Result<(usize, usize)> {
    // Find the end-of-central-directory record (no zip comment expected, but
    // scan the tail to be safe).
    let tail_start = bytes.len().saturating_sub(66_000);
    let tail = &bytes[tail_start..];
    let eocd_at = (0..tail.len().saturating_sub(21))
        .rev()
        .find(|&i| rd_u32(tail, i) == EOCD_SIG)
        .context("zip end-of-central-directory not found")?;
    let n_entries = rd_u16(tail, eocd_at + 10) as usize;
    let cdir_off = rd_u32(tail, eocd_at + 16) as usize;
    if cdir_off > bytes.len() {
        bail!("central directory offset past end of archive");
    }
    Ok((n_entries, cdir_off))
}

/// One member located in an in-memory archive: its name and the slice of
/// the archive holding its (STORED) payload. No payload bytes are copied.
struct ZipMember<'a> {
    name: String,
    data: &'a [u8],
}

/// Walk the central directory and resolve every STORED member to a payload
/// slice of `bytes`.
fn zip_members(bytes: &[u8]) -> Result<Vec<ZipMember<'_>>> {
    let (n_entries, cdir_off) = find_central_dir(bytes)?;
    let mut members = Vec::with_capacity(n_entries);
    let mut at = cdir_off;
    for _ in 0..n_entries {
        if at + 46 > bytes.len() || rd_u32(bytes, at) != CDIR_SIG {
            bail!("bad central directory entry");
        }
        let method = rd_u16(bytes, at + 10);
        let csize = rd_u32(bytes, at + 20) as usize;
        let name_len = rd_u16(bytes, at + 28) as usize;
        let extra_len = rd_u16(bytes, at + 30) as usize;
        let comment_len = rd_u16(bytes, at + 32) as usize;
        let local_off = rd_u32(bytes, at + 42) as usize;
        let name_end = at + 46 + name_len;
        if name_end > bytes.len() {
            bail!("central directory name truncated");
        }
        let name = String::from_utf8_lossy(&bytes[at + 46..name_end]).to_string();
        at = name_end + extra_len + comment_len;
        if method != 0 {
            bail!("{name}: compressed npz members unsupported (use np.savez, not savez_compressed)");
        }
        // Local header: name/extra lengths may differ from the central
        // directory's (extra field), so re-read them.
        if local_off + 30 > bytes.len() || rd_u32(bytes, local_off) != LOCAL_SIG {
            bail!("bad local header for {name}");
        }
        let lh_name = rd_u16(bytes, local_off + 26) as usize;
        let lh_extra = rd_u16(bytes, local_off + 28) as usize;
        let data_at = local_off + 30 + lh_name + lh_extra;
        let data_end = data_at
            .checked_add(csize)
            .filter(|&e| e <= bytes.len())
            .with_context(|| format!("member {name} payload truncated"))?;
        members.push(ZipMember { name, data: &bytes[data_at..data_end] });
    }
    Ok(members)
}

/// Parse every array from an in-memory npz archive. Each member is decoded
/// straight from its slice of `bytes` — the only copy is the byte→typed
/// decode itself.
pub fn read_npz_bytes(bytes: &[u8]) -> Result<Vec<NpzEntry>> {
    let members = zip_members(bytes)?;
    let mut entries = Vec::with_capacity(members.len());
    for m in members {
        let name = m.name.strip_suffix(".npy").unwrap_or(&m.name).to_string();
        let (shape, data) = parse_npy(m.data).with_context(|| format!("member {}", m.name))?;
        entries.push(NpzEntry { name, shape, data });
    }
    Ok(entries)
}

/// Read every array from an npz archive: one `read` of the whole file, then
/// slice-parsing via [`read_npz_bytes`] (no per-member seek+read round
/// trips).
pub fn read_npz(path: impl AsRef<Path>) -> Result<Vec<NpzEntry>> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    read_npz_bytes(&bytes)
}

/// Member names in an npz archive (cheap: walks the central directory only,
/// never decodes array payloads).
pub fn read_npz_names(path: impl AsRef<Path>) -> Result<Vec<String>> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    Ok(zip_members(&bytes)?
        .into_iter()
        .map(|m| m.name.strip_suffix(".npy").unwrap_or(&m.name).to_string())
        .collect())
}

// ---------------------------------------------------------------- writer --

/// CRC-32 (IEEE, reflected) — the zip checksum. Bitwise implementation: the
/// writer runs at build/bench time, not on the serving hot path.
fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Serialize one entry as a v1 npy member body (`<f4` or `<i4`).
fn npy_bytes(e: &NpzEntry) -> Vec<u8> {
    let descr = match e.data {
        NpzData::F32(_) => "<f4",
        NpzData::I32(_) => "<i4",
    };
    let dims = match e.shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", e.shape[0]),
        _ => format!(
            "({})",
            e.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header =
        format!("{{'descr': '{descr}', 'fortran_order': False, 'shape': {dims}, }}");
    while (10 + header.len() + 1) % 64 != 0 {
        header.push(' ');
    }
    header.push('\n');
    let mut out = b"\x93NUMPY\x01\x00".to_vec();
    out.extend((header.len() as u16).to_le_bytes());
    out.extend(header.as_bytes());
    match &e.data {
        NpzData::F32(v) => {
            for x in v {
                out.extend(x.to_le_bytes());
            }
        }
        NpzData::I32(v) => {
            for x in v {
                out.extend(x.to_le_bytes());
            }
        }
    }
    out
}

/// Serialize entries as an in-memory STORED npz archive (what `np.savez`
/// writes, minus compression) — readable by numpy and by this module.
pub fn npz_bytes(entries: &[NpzEntry]) -> Vec<u8> {
    let mut out = Vec::new();
    // (name, local header offset, crc, size) for the central directory.
    let mut dir: Vec<(String, usize, u32, usize)> = Vec::with_capacity(entries.len());
    for e in entries {
        let name = format!("{}.npy", e.name);
        let body = npy_bytes(e);
        let crc = crc32(&body);
        let off = out.len();
        out.extend(LOCAL_SIG.to_le_bytes());
        out.extend(20u16.to_le_bytes()); // version needed
        out.extend(0u16.to_le_bytes()); // flags
        out.extend(0u16.to_le_bytes()); // method: STORED
        out.extend(0u32.to_le_bytes()); // mod time/date
        out.extend(crc.to_le_bytes());
        out.extend((body.len() as u32).to_le_bytes()); // csize
        out.extend((body.len() as u32).to_le_bytes()); // usize
        out.extend((name.len() as u16).to_le_bytes());
        out.extend(0u16.to_le_bytes()); // extra len
        out.extend(name.as_bytes());
        out.extend(&body);
        dir.push((name, off, crc, body.len()));
    }
    let cdir_off = out.len();
    for (name, off, crc, size) in &dir {
        out.extend(CDIR_SIG.to_le_bytes());
        out.extend(20u16.to_le_bytes()); // version made by
        out.extend(20u16.to_le_bytes()); // version needed
        out.extend(0u16.to_le_bytes()); // flags
        out.extend(0u16.to_le_bytes()); // method
        out.extend(0u32.to_le_bytes()); // mod time/date
        out.extend(crc.to_le_bytes());
        out.extend((*size as u32).to_le_bytes()); // csize
        out.extend((*size as u32).to_le_bytes()); // usize
        out.extend((name.len() as u16).to_le_bytes());
        out.extend(0u16.to_le_bytes()); // extra len
        out.extend(0u16.to_le_bytes()); // comment len
        out.extend(0u16.to_le_bytes()); // disk number
        out.extend(0u16.to_le_bytes()); // internal attrs
        out.extend(0u32.to_le_bytes()); // external attrs
        out.extend((*off as u32).to_le_bytes());
        out.extend(name.as_bytes());
    }
    let cdir_size = out.len() - cdir_off;
    out.extend(EOCD_SIG.to_le_bytes());
    out.extend(0u16.to_le_bytes()); // disk number
    out.extend(0u16.to_le_bytes()); // cdir disk
    out.extend((dir.len() as u16).to_le_bytes()); // entries on disk
    out.extend((dir.len() as u16).to_le_bytes()); // entries total
    out.extend((cdir_size as u32).to_le_bytes());
    out.extend((cdir_off as u32).to_le_bytes());
    out.extend(0u16.to_le_bytes()); // comment len
    out
}

/// Write entries to an npz file on disk (STORED, numpy-readable). Used by
/// benches and tests to synthesize weight archives without python.
pub fn write_npz(path: impl AsRef<Path>, entries: &[NpzEntry]) -> Result<()> {
    let path = path.as_ref();
    let bytes = npz_bytes(entries);
    let mut f =
        File::create(path).with_context(|| format!("create {}", path.display()))?;
    f.write_all(&bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_parse() {
        let (d, f, s) =
            parse_npy_header("{'descr': '<f4', 'fortran_order': False, 'shape': (2, 3), }")
                .unwrap();
        assert_eq!(d, "<f4");
        assert!(!f);
        assert_eq!(s, vec![2, 3]);
    }

    #[test]
    fn header_scalar_and_1d() {
        let (_, _, s) =
            parse_npy_header("{'descr': '<i4', 'fortran_order': False, 'shape': (), }").unwrap();
        assert!(s.is_empty());
        let (_, _, s) =
            parse_npy_header("{'descr': '<i4', 'fortran_order': False, 'shape': (5,), }")
                .unwrap();
        assert_eq!(s, vec![5]);
    }

    #[test]
    fn npy_roundtrip_f32() {
        // Hand-build a v1 npy: magic, ver, hlen, header, payload.
        let header = "{'descr': '<f4', 'fortran_order': False, 'shape': (2, 2), }";
        let mut h = header.to_string();
        while (10 + h.len() + 1) % 64 != 0 {
            h.push(' ');
        }
        h.push('\n');
        let mut bytes = b"\x93NUMPY\x01\x00".to_vec();
        bytes.extend((h.len() as u16).to_le_bytes());
        bytes.extend(h.as_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.5] {
            bytes.extend(v.to_le_bytes());
        }
        let (shape, data) = parse_npy(&bytes).unwrap();
        assert_eq!(shape, vec![2, 2]);
        match data {
            NpzData::F32(v) => assert_eq!(v, vec![1.0, 2.0, 3.0, 4.5]),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn npz_write_read_roundtrip_in_memory() {
        let entries = vec![
            NpzEntry {
                name: "w".into(),
                shape: vec![2, 3],
                data: NpzData::F32(vec![1.0, -2.0, 3.5, 0.0, 4.25, -0.5]),
            },
            NpzEntry {
                name: "y".into(),
                shape: vec![4],
                data: NpzData::I32(vec![0, 1, 2, 3]),
            },
        ];
        let bytes = npz_bytes(&entries);
        let back = read_npz_bytes(&bytes).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "w");
        assert_eq!(back[0].shape, vec![2, 3]);
        match &back[0].data {
            NpzData::F32(v) => assert_eq!(v, &[1.0, -2.0, 3.5, 0.0, 4.25, -0.5]),
            _ => panic!("wrong dtype"),
        }
        assert_eq!(back[1].as_i32().unwrap(), &[0, 1, 2, 3]);
    }

    #[test]
    fn into_tensor_matches_to_tensor() {
        let e = NpzEntry {
            name: "x".into(),
            shape: vec![3],
            data: NpzData::I32(vec![-1, 0, 7]),
        };
        let copied = e.to_tensor();
        let moved = e.into_tensor();
        assert_eq!(copied, moved);
        assert_eq!(moved.data(), &[-1.0, 0.0, 7.0]);
    }

    #[test]
    fn truncated_archives_error_not_panic() {
        let entries = vec![NpzEntry {
            name: "w".into(),
            shape: vec![8],
            data: NpzData::F32(vec![1.0; 8]),
        }];
        let bytes = npz_bytes(&entries);
        // Every truncation point must produce Err, never a panic.
        for cut in 0..bytes.len() {
            let _ = read_npz_bytes(&bytes[..cut]);
        }
        assert!(read_npz_bytes(&bytes).is_ok());
    }

    /// Hand-build a v1 npy member with an arbitrary (possibly wrong) body.
    fn raw_npy(descr: &str, shape: &str, body: &[u8]) -> Vec<u8> {
        let header = format!("{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape}, }}");
        let mut h = header;
        while (10 + h.len() + 1) % 64 != 0 {
            h.push(' ');
        }
        h.push('\n');
        let mut bytes = b"\x93NUMPY\x01\x00".to_vec();
        bytes.extend((h.len() as u16).to_le_bytes());
        bytes.extend(h.as_bytes());
        bytes.extend_from_slice(body);
        bytes
    }

    fn f32_body(vals: &[f32]) -> Vec<u8> {
        vals.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    #[test]
    fn nan_and_inf_weights_are_rejected_typed() {
        let bad = raw_npy("<f4", "(4,)", &f32_body(&[1.0, 2.0, f32::NAN, 4.0]));
        let err = parse_npy(&bad).unwrap_err().to_string();
        assert!(err.contains("non-finite value (NaN/Inf) at element 2"), "{err}");
        let bad = raw_npy("<f4", "(2,)", &f32_body(&[f32::INFINITY, 0.0]));
        let err = parse_npy(&bad).unwrap_err().to_string();
        assert!(err.contains("at element 0"), "{err}");
    }

    #[test]
    fn f64_overflowing_f32_range_is_rejected_after_narrowing() {
        let body: Vec<u8> = [1e300f64, 1.0].iter().flat_map(|v| v.to_le_bytes()).collect();
        let err = parse_npy(&raw_npy("<f8", "(2,)", &body)).unwrap_err().to_string();
        assert!(err.contains("non-finite"), "1e300 narrows to +Inf and must fail: {err}");
    }

    #[test]
    fn zero_dim_shapes_are_rejected_typed() {
        let err = parse_npy(&raw_npy("<f4", "(0, 3)", &[])).unwrap_err().to_string();
        assert!(err.contains("zero-sized dimension in shape [0, 3]"), "{err}");
        // Scalars (shape ()) hold one element and stay valid.
        let ok = parse_npy(&raw_npy("<f4", "()", &f32_body(&[7.0])));
        assert!(ok.is_ok(), "{:?}", ok.err());
    }

    #[test]
    fn body_length_must_match_exactly_in_both_directions() {
        // Short: 3 floats promised, 2 present.
        let err =
            parse_npy(&raw_npy("<f4", "(3,)", &f32_body(&[1.0, 2.0]))).unwrap_err().to_string();
        assert!(err.contains("expected 12 bytes, got 8"), "{err}");
        // Long: trailing garbage after the promised payload means the file
        // is corrupt — the old prefix-decode would have hidden this.
        let err = parse_npy(&raw_npy("<f4", "(2,)", &f32_body(&[1.0, 2.0, 3.0])))
            .unwrap_err()
            .to_string();
        assert!(err.contains("expected 8 bytes, got 12"), "{err}");
        // Integer members get the same exactness.
        let err = parse_npy(&raw_npy("<i4", "(2,)", &[0u8; 7])).unwrap_err().to_string();
        assert!(err.contains("expected 8 bytes, got 7"), "{err}");
    }

    // Reading real numpy-written npz files is covered by the integration test
    // rust/tests/npz_interop.rs against artifacts/ produced by `make artifacts`.
}
