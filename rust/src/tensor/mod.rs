//! Minimal dense-tensor substrate (row-major f32 / i32) with npy/npz I/O.
//!
//! Deliberately small: the quantization library, the rust-native NN forward
//! engine and the fixed-point GEMMs only need shaped, contiguous, row-major
//! buffers plus a couple of views. The npz loaders interoperate with the
//! build-time python side (numpy `savez`) and the `xla` crate's `Literal`.
mod npz;
mod tensorf;

pub use npz::{
    npz_bytes, read_npz, read_npz_bytes, read_npz_names, write_npz, NpzData, NpzEntry, NpzError,
};
pub use tensorf::Tensor;
