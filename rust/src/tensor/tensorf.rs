//! Row-major f32 tensor with the handful of ops the stack needs.

use anyhow::{bail, Result};

/// Dense row-major f32 tensor of arbitrary rank.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(&mut f).collect() }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Size of dimension `d`.
    pub fn dim(&self, d: usize) -> usize {
        self.shape[d]
    }

    /// Reshape without copying; total element count must match.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {shape:?}: element count mismatch", self.shape);
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// 2-D element accessor (row-major).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Row view of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// 2-D transpose (copying).
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2, "transpose2 on rank {}", self.rank());
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor { shape: vec![n, m], data: out }
    }

    /// Elementwise maximum with a scalar (ReLU when s = 0).
    pub fn max_scalar(&self, s: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| x.max(s)).collect(),
        }
    }

    /// Largest absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Largest absolute value.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).fold(0.0f32, f32::max)
    }

    /// Slice of the first `n` rows of a rank-2 tensor (copying).
    pub fn take_rows(&self, n: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert!(n <= self.shape[0]);
        Tensor::new(&[n, self.shape[1]], self.data[..n * self.shape[1]].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.dim(1), 3);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn shape_mismatch_panics() {
        Tensor::new(&[2, 2], vec![1.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::from_fn(&[3, 4], |i| i as f32);
        let tt = t.transpose2().transpose2();
        assert_eq!(t, tt);
        assert_eq!(t.transpose2().at2(2, 1), t.at2(1, 2));
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[4, 2]);
        assert!(t.reshape(&[2, 4]).is_ok());
        assert!(t.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::new(&[3], vec![1., 2., 3.]);
        let b = Tensor::new(&[3], vec![1., 2.5, 2.]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
