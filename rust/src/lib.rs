//! `lqr` — Local Quantization Region inference stack.
//!
//! Reproduction of "Deploy Large-Scale Deep Neural Networks in Resource
//! Constrained IoT Devices with Local Quantization Region" (Yang et al.,
//! 2018). See DESIGN.md for the system inventory and per-experiment index.
//!
//! Start with `rust/README.md` (crate map, the quantized conv/GEMM data
//! flow, how to verify and benchmark, the runtime-knob table) and
//! `docs/kernel-dispatch.md` (the SIMD kernel contract and the checklist
//! for adding the next ISA arm).
//!
//! Crate layout:
//! - [`util`] — hand-rolled infra (RNG, JSON, CLI, thread pool, stats, prop).
//! - [`tensor`] — minimal f32/int ndarray substrate with npz I/O.
//! - [`quant`] — the paper's contribution: DQ / LQ schemes, region
//!   partitioning, bit codecs, LUT construction, error analysis.
//! - [`nn`] — network graph, rust-native forward executor, architecture zoo
//!   (full AlexNet / VGG-16 + the trained Mini variants), op counting.
//! - [`fixedpoint`] — f32 / i8 / packed low-bit / LUT GEMM kernels.
//! - [`runtime`] — PJRT artifact loading + execution (xla crate).
//! - [`coordinator`] — serving: router, dynamic batcher, workers, metrics.
//! - [`platform`] — Edison/Silvermont cost model + FPGA simulator.
//! - [`dataset`] — synthetic dataset generation / npz loading.
//! - [`eval`] — accuracy harness, sweeps, report formatting.
pub mod util;
pub mod tensor;
pub mod quant;
pub mod nn;
pub mod fixedpoint;
pub mod runtime;
pub mod coordinator;
pub mod platform;
pub mod dataset;
pub mod eval;
