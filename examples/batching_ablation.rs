//! Ablation: dynamic-batching policy (max_batch x max_wait) vs latency and
//! throughput — the design-choice study DESIGN.md calls out for the L3
//! coordinator. Uses a fixed-cost mock backend so the measurement isolates
//! the *policy*, not the model: cost(batch) = base + per_row * rows, the
//! amortization regime where batching pays.
//!
//! ```sh
//! cargo run --release --example batching_ablation
//! ```

use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use lqr::coordinator::backend::Backend;
use lqr::coordinator::{Coordinator, CoordinatorConfig};
use lqr::eval::TableFmt;
use lqr::tensor::Tensor;
use lqr::util::rng::Rng;
use lqr::util::stats::percentile;

/// Mock with batch-size-dependent cost: base 2 ms + 0.25 ms/row.
struct AmortizedBackend;

impl Backend for AmortizedBackend {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let n = batch.dim(0);
        std::thread::sleep(Duration::from_micros(2000 + 250 * n as u64));
        Ok(Tensor::zeros(&[n, 4]))
    }

    fn describe(&self) -> String {
        "amortized-mock".into()
    }
}

fn run(max_batch: usize, max_wait_ms: u64, rate: f64, total: usize) -> (f64, f64, f64, f64) {
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            queue_capacity: 8192,
            ..Default::default()
        },
        Box::new(|| Ok(Box::new(AmortizedBackend) as Box<dyn Backend>)),
    )
    .unwrap();
    let mut rng = Rng::new(1);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..total)
        .map(|_| {
            let rx = coord.submit(Tensor::zeros(&[1, 1, 4, 4])).unwrap();
            std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
            rx
        })
        .collect();
    let lat: Vec<f64> = rxs
        .into_iter()
        .map(|rx| {
            let r = rx.recv().unwrap().expect("mock backend never fails");
            (r.queue_time + r.execute_time).as_secs_f64() * 1e3
        })
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.shutdown();
    // Same nearest-rank definition as serve_workload / Summary, so the
    // ablation's tail numbers are comparable with the saturation bench.
    (total as f64 / wall, percentile(&lat, 0.5), percentile(&lat, 0.99), m.mean_batch_size())
}

fn main() {
    let _ = AtomicU64::new(0);
    let rate = 400.0;
    let total = 300;
    let mut t = TableFmt::new(
        &format!("Batching-policy ablation (cost = 2ms + 0.25ms/row, offered {rate} req/s)"),
        &["max_batch", "max_wait", "achieved req/s", "p50 ms", "p99 ms", "mean batch"],
    );
    for &mb in &[1usize, 4, 8, 16] {
        for &mw in &[1u64, 4, 16] {
            let (thr, p50, p99, mean) = run(mb, mw, rate, total);
            t.row(&[
                mb.to_string(),
                format!("{mw} ms"),
                format!("{thr:.0}"),
                format!("{p50:.2}"),
                format!("{p99:.2}"),
                format!("{mean:.2}"),
            ]);
        }
    }
    t.print();
    println!(
        "reading: max_batch=1 saturates at ~1/(2.25ms) = 444 req/s with no headroom;\n\
         batching amortizes the 2ms base cost (throughput rises with max_batch)\n\
         while max_wait trades p50 latency for batch fill — the classic frontier."
    );
}
