//! Wire-protocol walkthrough: typed status codes, retry classification,
//! and the hardened-ingress behaviors, demonstrated against an in-process
//! server over a mock backend (no artifacts needed).
//!
//! Shows what a remote client of `lqr serve-tcp` sees: a successful round,
//! the health built-in, a terminal rejection (`NoRoute`), an in-sync
//! `BadRequest` (the connection keeps working afterwards), and accept-time
//! shedding (`Busy`) when the handler pool is full — each classified with
//! `ClientError::retryable()`.
//!
//! ```sh
//! cargo run --release --example wire_client
//! ```

use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use lqr::coordinator::backend::{Backend, MockBackend};
use lqr::coordinator::net::{ImageSpec, NetClient, NetConfig, NetServer};
use lqr::coordinator::router::Router;
use lqr::coordinator::CoordinatorConfig;
use lqr::tensor::Tensor;

fn report(label: &str, result: std::result::Result<(Vec<f32>, usize), lqr::coordinator::net::ClientError>) {
    match result {
        Ok((logits, predicted)) => {
            println!("{label:<28} Ok: predicted={predicted} logits[0]={:.2}", logits[0]);
        }
        Err(e) => {
            let kind = match e.wire_status() {
                Some(s) => format!("{s:?}"),
                None => "transport".into(),
            };
            println!("{label:<28} {kind} (retryable={}): {e}", e.retryable());
        }
    }
}

fn main() -> Result<()> {
    lqr::util::logging::init();

    // A tiny mock route: logits[0] = sum of the 2x2 input pixels.
    let mut router = Router::new();
    router.add_route(
        "demo",
        CoordinatorConfig::default(),
        Box::new(|| {
            Ok(Box::new(MockBackend {
                classes: 4,
                delay: Duration::from_millis(1),
                calls: Arc::new(AtomicU64::new(0)),
            }) as Box<dyn Backend>)
        }),
    )?;
    let spec = ImageSpec { c: 1, h: 2, w: 2 };
    let cfg = NetConfig {
        max_conns: 2, // small on purpose, to demonstrate Busy shedding
        io_timeout: Duration::from_secs(5),
        ..Default::default()
    };
    let server = NetServer::serve_with("127.0.0.1:0", Arc::new(router), spec, cfg)?;
    println!("serving on {} (max_conns=2)\n", server.addr);

    let mut client = NetClient::connect(server.addr)?;
    client.set_io_timeout(Some(Duration::from_secs(10)))?;

    // 1. A successful round: Ok status, logits + argmax.
    report("classify demo", client.classify("demo", &Tensor::filled(&[1, 1, 2, 2], 0.25)));

    // 2. The health built-in: readiness + queue/pool occupancy.
    println!("{:<28} {}", "health", client.health().map_err(anyhow::Error::from)?);

    // 3. Terminal rejection: no such route. retryable=false — don't loop.
    report("classify missing route", client.classify("nope", &Tensor::filled(&[1, 1, 2, 2], 0.25)));

    // 4. In-sync BadRequest: wrong image geometry. The reply is typed and
    //    the stream stays usable — the next round on the SAME connection
    //    succeeds.
    report("classify wrong shape", client.classify("demo", &Tensor::filled(&[1, 1, 3, 3], 0.25)));
    report("same conn, next round", client.classify("demo", &Tensor::filled(&[1, 1, 2, 2], 1.0)));

    // 5. Accept-time shedding: hold both pool slots, then connect once more.
    //    The extra connection gets a typed Busy reply (retryable=true) and
    //    is closed; the held connections keep working.
    let mut holder = NetClient::connect(server.addr)?;
    holder.set_io_timeout(Some(Duration::from_secs(10)))?;
    holder.classify("demo", &Tensor::filled(&[1, 1, 2, 2], 0.5)).map_err(anyhow::Error::from)?;
    let mut shed = NetClient::connect(server.addr)?;
    shed.set_io_timeout(Some(Duration::from_secs(10)))?;
    report("flood past max_conns", shed.classify("demo", &Tensor::filled(&[1, 1, 2, 2], 0.5)));
    report("holder still serving", holder.classify("demo", &Tensor::filled(&[1, 1, 2, 2], 0.5)));

    let metrics = server.shutdown();
    println!("\n{}", metrics.summary());
    Ok(())
}
