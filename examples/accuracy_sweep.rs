//! Tables 1 + 2 / Fig. 9 — accuracy under quantization.
//!
//! Runs the paper's accuracy protocol on the trained mini models: weights
//! quantized offline to 8-bit LQ, activations quantized at runtime with DQ
//! (per-layer scale, §IV.B) or LQ (per-region scale, the contribution),
//! across 8/6/4/2-bit precision.
//!
//! ```sh
//! cargo run --release --example accuracy_sweep -- --limit 512
//! ```

use anyhow::Result;
use lqr::eval::sweep;
use lqr::util::cli::Args;

fn main() -> Result<()> {
    lqr::util::logging::init();
    let p = Args::new("accuracy_sweep", "Tables 1-2 / Fig. 9 accuracy sweeps")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("bits", "8,6,4,2", "activation bit widths")
        .flag("limit", "512", "validation images")
        .parse_from(&std::env::args().skip(1).collect::<Vec<_>>())
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let artifacts = p.get("artifacts");
    let limit = p.get_usize("limit");
    sweep::table1(artifacts, limit)?.print();
    sweep::table2(artifacts, &p.get_usize_list("bits"), limit)?.print();
    Ok(())
}
