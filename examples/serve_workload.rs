//! E2E serving driver (the repo's end-to-end validation run) plus the
//! saturation benchmark for the sharded batching core.
//!
//! Two modes:
//!
//! **Artifact mode** (default): loads the trained MiniAlexNet artifacts,
//! starts the coordinator with dynamic batching, drives a Poisson request
//! stream sampled from the validation set at several arrival rates, and
//! reports latency percentiles (p50/p99/p999), throughput, achieved batch
//! sizes and accuracy for both the f32 baseline and the 8-bit LQ variant.
//! Recorded in EXPERIMENTS.md §E2E.
//!
//! **Saturation mode** (`--saturate`): needs no artifacts. Drives a
//! fixed-cost synthetic backend to the throughput knee — ramping offered
//! load from multiple submitter threads at 1/2/4/8 workers, sharded
//! (one shard per worker, work stealing on) vs single-queue — and records
//! p50/p99/p999 latency plus the peak sustained RPS per configuration to
//! `BENCH_serve.json` at the repo root. `--smoke` shrinks the sweep to a
//! few seconds for CI.
//!
//! ```sh
//! cargo run --release --example serve_workload [artifacts_dir]
//! cargo run --release --example serve_workload -- --saturate [--smoke]
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use lqr::coordinator::backend::{Backend, PjrtBackend};
use lqr::coordinator::{Coordinator, CoordinatorConfig, Priority, SubmitError};
use lqr::dataset::Dataset;
use lqr::eval::TableFmt;
use lqr::tensor::Tensor;
use lqr::util::rng::Rng;
use lqr::util::stats::percentile;

// ------------------------------------------------------------- artifacts --

struct RunResult {
    throughput: f64,
    accuracy: f64,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
    mean_batch: f64,
    errors: usize,
}

fn drive(
    artifacts: &str,
    variant: &str,
    rate: f64,
    total: usize,
    ds: &Dataset,
) -> Result<RunResult> {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 8,
        max_wait: Duration::from_millis(4),
        queue_capacity: 4096,
        ..Default::default()
    };
    let (a, v) = (artifacts.to_string(), variant.to_string());
    let coord = Coordinator::start(
        cfg,
        Box::new(move || Ok(Box::new(PjrtBackend::open(&a, "minialexnet", &v)?) as Box<dyn Backend>)),
    )?;

    let mut rng = Rng::new(42);
    let mut rxs = Vec::with_capacity(total);
    let mut labels = Vec::with_capacity(total);
    let t0 = Instant::now();
    for _ in 0..total {
        let i = ds.sample(&mut rng);
        labels.push(ds.labels[i]);
        loop {
            match coord.submit(ds.image(i)) {
                Ok(rx) => {
                    rxs.push(rx);
                    break;
                }
                Err(SubmitError::QueueFull(_)) => std::thread::sleep(Duration::from_micros(100)),
                // Shut down / dead pool: retrying can never succeed.
                Err(e) => anyhow::bail!("submit failed: {e}"),
            }
        }
        std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
    }
    let mut hits = 0usize;
    let mut errors = 0usize;
    let mut lat_ms: Vec<f64> = Vec::with_capacity(total);
    let submit_done = t0.elapsed();
    for (rx, label) in rxs.into_iter().zip(labels) {
        match rx.recv()? {
            Ok(r) => {
                lat_ms.push((r.queue_time + r.execute_time).as_secs_f64() * 1e3);
                if r.predicted as i32 == label {
                    hits += 1;
                }
            }
            // Typed error reply (shed/expired/backend): counted per run.
            Err(_) => errors += 1,
        }
    }
    anyhow::ensure!(!lat_ms.is_empty(), "every request errored — nothing to report");
    let wall = t0.elapsed().as_secs_f64().max(submit_done.as_secs_f64());
    let m = coord.shutdown();
    Ok(RunResult {
        throughput: total as f64 / wall,
        accuracy: hits as f64 / (total - errors).max(1) as f64,
        p50_ms: percentile(&lat_ms, 0.50),
        p99_ms: percentile(&lat_ms, 0.99),
        p999_ms: percentile(&lat_ms, 0.999),
        mean_batch: m.mean_batch_size(),
        errors,
    })
}

fn artifact_mode(artifacts: &str) -> Result<()> {
    let ds = Dataset::load(format!("{artifacts}/data"), "val")?;
    let total = 400;

    let mut t = TableFmt::new(
        "E2E serving: MiniAlexNet, Poisson arrivals, dynamic batching (max_batch=8, max_wait=4ms)",
        &[
            "variant",
            "offered req/s",
            "achieved req/s",
            "top-1",
            "p50 ms",
            "p99 ms",
            "p999 ms",
            "mean batch",
            "errors",
        ],
    );
    for variant in ["f32", "lq"] {
        for rate in [100.0, 400.0, 1600.0] {
            let r = drive(artifacts, variant, rate, total, &ds)?;
            t.row(&[
                variant.into(),
                format!("{rate:.0}"),
                format!("{:.0}", r.throughput),
                format!("{:.1}%", r.accuracy * 100.0),
                format!("{:.2}", r.p50_ms),
                format!("{:.2}", r.p99_ms),
                format!("{:.2}", r.p999_ms),
                format!("{:.2}", r.mean_batch),
                r.errors.to_string(),
            ]);
        }
    }
    t.print();
    Ok(())
}

// ------------------------------------------------------------ saturation --

/// Fixed-cost backend for the saturation sweep: cost(batch) = base +
/// per_row * rows — the amortization regime where batching pays. Spin-free
/// (sleep), so the measurement is the *scheduling plane*, not the CPU.
struct SyntheticBackend {
    base_us: u64,
    per_row_us: u64,
}

impl Backend for SyntheticBackend {
    fn run_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let n = batch.dim(0);
        std::thread::sleep(Duration::from_micros(self.base_us + self.per_row_us * n as u64));
        Ok(Tensor::zeros(&[n, 4]))
    }

    fn describe(&self) -> String {
        "synthetic-fixed-cost".into()
    }
}

const BASE_US: u64 = 200;
const PER_ROW_US: u64 = 25;

struct SatRow {
    workers: usize,
    mode: &'static str,
    shards: usize,
    steal: bool,
    offered_rps: f64,
    achieved_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
    completed: u64,
    errors: u64,
}

/// One measured point: `total` requests offered open-loop at `offered_rps`
/// from `submitters` threads (~20% on the bulk lane); overflow is shed, not
/// retried, so the offered rate stays honest under saturation.
fn sat_point(
    workers: usize,
    mode: &'static str,
    offered_rps: f64,
    total: usize,
    submitters: usize,
) -> Result<SatRow> {
    let shards = match mode {
        "sharded" => workers,
        _ => 1,
    };
    let cfg = CoordinatorConfig {
        workers,
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_capacity: 4096,
        shards,
        steal: mode == "sharded",
        ..Default::default()
    };
    let steal = cfg.steal;
    let coord = Arc::new(Coordinator::start(
        cfg,
        Box::new(|| {
            Ok(Box::new(SyntheticBackend { base_us: BASE_US, per_row_us: PER_ROW_US })
                as Box<dyn Backend>)
        }),
    )?);

    let errors = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..submitters)
        .map(|s| {
            let coord = Arc::clone(&coord);
            let errors = Arc::clone(&errors);
            let per_thread = total / submitters;
            let rate = offered_rps / submitters as f64;
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xBEEF + s as u64);
                let mut rxs = Vec::with_capacity(per_thread);
                for i in 0..per_thread {
                    let pri = if i % 5 == 0 { Priority::Bulk } else { Priority::Interactive };
                    match coord.submit_with_options(Tensor::zeros(&[1, 1, 4, 4]), None, pri) {
                        Ok(rx) => rxs.push(rx),
                        // Open loop: overload is shed and counted, never
                        // retried (retrying would throttle the offered rate).
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
                }
                let mut lat_ms = Vec::with_capacity(rxs.len());
                for rx in rxs {
                    match rx.recv_timeout(Duration::from_secs(30)) {
                        Ok(Ok(r)) => {
                            lat_ms.push((r.queue_time + r.execute_time).as_secs_f64() * 1e3)
                        }
                        _ => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                lat_ms
            })
        })
        .collect();
    let mut lat_ms = Vec::with_capacity(total);
    for h in handles {
        lat_ms.extend(h.join().expect("submitter thread panicked"));
    }
    let wall = t0.elapsed().as_secs_f64();
    anyhow::ensure!(!lat_ms.is_empty(), "every request errored at {offered_rps} req/s");
    Ok(SatRow {
        workers,
        mode,
        shards,
        steal,
        offered_rps,
        achieved_rps: lat_ms.len() as f64 / wall,
        p50_ms: percentile(&lat_ms, 0.50),
        p99_ms: percentile(&lat_ms, 0.99),
        p999_ms: percentile(&lat_ms, 0.999),
        completed: lat_ms.len() as u64,
        errors: errors.load(Ordering::Relaxed),
    })
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_bench_json(rows: &[SatRow], smoke: bool) -> std::io::Result<()> {
    // Peak sustained RPS per (workers, mode): the knee of the ramp.
    let mut peaks: Vec<(usize, &str, f64)> = Vec::new();
    for r in rows {
        match peaks.iter_mut().find(|(w, m, _)| *w == r.workers && *m == r.mode) {
            Some(p) => p.2 = p.2.max(r.achieved_rps),
            None => peaks.push((r.workers, r.mode, r.achieved_rps)),
        }
    }
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"serve_saturation\",\n");
    s.push_str("  \"schema\": 1,\n");
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str(&format!(
        "  \"backend\": \"{}\",\n",
        json_escape(&format!(
            "synthetic: {BASE_US}us + {PER_ROW_US}us/row, max_batch=8, max_wait=2ms"
        ))
    ));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workers\": {}, \"mode\": \"{}\", \"shards\": {}, \"steal\": {}, \
             \"offered_rps\": {:.1}, \"achieved_rps\": {:.1}, \"p50_ms\": {:.3}, \
             \"p99_ms\": {:.3}, \"p999_ms\": {:.3}, \"completed\": {}, \"errors\": {}}}{}\n",
            r.workers,
            r.mode,
            r.shards,
            r.steal,
            r.offered_rps,
            r.achieved_rps,
            r.p50_ms,
            r.p99_ms,
            r.p999_ms,
            r.completed,
            r.errors,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"peaks\": [\n");
    for (i, (w, m, rps)) in peaks.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workers\": {w}, \"mode\": \"{m}\", \"peak_rps\": {rps:.1}}}{}\n",
            if i + 1 < peaks.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    std::fs::write(path, s)?;
    println!("wrote {path}");
    Ok(())
}

fn saturate_mode(smoke: bool) -> Result<()> {
    let (worker_counts, ramp, total, submitters): (&[usize], &[f64], usize, usize) = if smoke {
        (&[1, 4], &[1000.0, 4000.0], 400, 2)
    } else {
        (&[1, 2, 4, 8], &[500.0, 1000.0, 2000.0, 4000.0, 8000.0], 2000, 4)
    };
    let mut t = TableFmt::new(
        &format!(
            "Saturation ramp: synthetic backend ({BASE_US}us + {PER_ROW_US}us/row), \
             sharded (1 shard/worker, stealing) vs single queue"
        ),
        &[
            "workers",
            "mode",
            "offered req/s",
            "achieved req/s",
            "p50 ms",
            "p99 ms",
            "p999 ms",
            "errors",
        ],
    );
    let mut rows = Vec::new();
    for &workers in worker_counts {
        for mode in ["single", "sharded"] {
            for &rate in ramp {
                let r = sat_point(workers, mode, rate, total, submitters)?;
                t.row(&[
                    workers.to_string(),
                    mode.into(),
                    format!("{rate:.0}"),
                    format!("{:.0}", r.achieved_rps),
                    format!("{:.2}", r.p50_ms),
                    format!("{:.2}", r.p99_ms),
                    format!("{:.2}", r.p999_ms),
                    r.errors.to_string(),
                ]);
                rows.push(r);
            }
        }
    }
    t.print();
    write_bench_json(&rows, smoke)?;
    Ok(())
}

fn main() -> Result<()> {
    lqr::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--saturate") {
        return saturate_mode(args.iter().any(|a| a == "--smoke"));
    }
    let artifacts =
        args.iter().find(|a| !a.starts_with("--")).cloned().unwrap_or_else(|| "artifacts".into());
    artifact_mode(&artifacts)
}
