//! E2E serving driver (the repo's end-to-end validation run).
//!
//! Loads the trained MiniAlexNet artifacts, starts the coordinator with
//! dynamic batching, drives a Poisson request stream sampled from the
//! validation set at several arrival rates, and reports latency percentiles,
//! throughput, achieved batch sizes and accuracy for both the f32 baseline
//! and the 8-bit LQ variant. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! cargo run --release --example serve_workload [artifacts_dir]
//! ```

use std::time::{Duration, Instant};

use anyhow::Result;
use lqr::coordinator::backend::{Backend, PjrtBackend};
use lqr::coordinator::{Coordinator, CoordinatorConfig};
use lqr::dataset::Dataset;
use lqr::eval::TableFmt;
use lqr::util::rng::Rng;

struct RunResult {
    throughput: f64,
    accuracy: f64,
    p50_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
    errors: usize,
}

fn drive(
    artifacts: &str,
    variant: &str,
    rate: f64,
    total: usize,
    ds: &Dataset,
) -> Result<RunResult> {
    let cfg = CoordinatorConfig {
        workers: 1,
        max_batch: 8,
        max_wait: Duration::from_millis(4),
        queue_capacity: 4096,
        ..Default::default()
    };
    let (a, v) = (artifacts.to_string(), variant.to_string());
    let coord = Coordinator::start(
        cfg,
        Box::new(move || Ok(Box::new(PjrtBackend::open(&a, "minialexnet", &v)?) as Box<dyn Backend>)),
    )?;

    let mut rng = Rng::new(42);
    let mut rxs = Vec::with_capacity(total);
    let mut labels = Vec::with_capacity(total);
    let t0 = Instant::now();
    for _ in 0..total {
        let i = ds.sample(&mut rng);
        labels.push(ds.labels[i]);
        loop {
            match coord.submit(ds.image(i)) {
                Ok(rx) => {
                    rxs.push(rx);
                    break;
                }
                Err(lqr::coordinator::SubmitError::QueueFull(_)) => {
                    std::thread::sleep(Duration::from_micros(100))
                }
                // Shut down / dead pool: retrying can never succeed.
                Err(e) => anyhow::bail!("submit failed: {e}"),
            }
        }
        std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
    }
    let mut hits = 0usize;
    let mut errors = 0usize;
    let mut lat_ms: Vec<f64> = Vec::with_capacity(total);
    let submit_done = t0.elapsed();
    for (rx, label) in rxs.into_iter().zip(labels) {
        match rx.recv()? {
            Ok(r) => {
                lat_ms.push((r.queue_time + r.execute_time).as_secs_f64() * 1e3);
                if r.predicted as i32 == label {
                    hits += 1;
                }
            }
            // Typed error reply (shed/expired/backend): counted per run.
            Err(_) => errors += 1,
        }
    }
    anyhow::ensure!(!lat_ms.is_empty(), "every request errored — nothing to report");
    let wall = t0.elapsed().as_secs_f64().max(submit_done.as_secs_f64());
    let m = coord.shutdown();
    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let pct = |p: f64| lat_ms[((lat_ms.len() - 1) as f64 * p) as usize];
    Ok(RunResult {
        throughput: total as f64 / wall,
        accuracy: hits as f64 / (total - errors).max(1) as f64,
        p50_ms: pct(0.50),
        p99_ms: pct(0.99),
        mean_batch: m.mean_batch_size(),
        errors,
    })
}

fn main() -> Result<()> {
    lqr::util::logging::init();
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let ds = Dataset::load(format!("{artifacts}/data"), "val")?;
    let total = 400;

    let mut t = TableFmt::new(
        "E2E serving: MiniAlexNet, Poisson arrivals, dynamic batching (max_batch=8, max_wait=4ms)",
        &[
            "variant",
            "offered req/s",
            "achieved req/s",
            "top-1",
            "p50 ms",
            "p99 ms",
            "mean batch",
            "errors",
        ],
    );
    for variant in ["f32", "lq"] {
        for rate in [100.0, 400.0, 1600.0] {
            let r = drive(&artifacts, variant, rate, total, &ds)?;
            t.row(&[
                variant.into(),
                format!("{rate:.0}"),
                format!("{:.0}", r.throughput),
                format!("{:.1}%", r.accuracy * 100.0),
                format!("{:.2}", r.p50_ms),
                format!("{:.2}", r.p99_ms),
                format!("{:.2}", r.mean_batch),
                r.errors.to_string(),
            ]);
        }
    }
    t.print();
    Ok(())
}
