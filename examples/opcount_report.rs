//! Table 3 — multiply/add counts, original vs 2-bit LUT scheme.
//!
//! Analytic counts over the *full* AlexNet and VGG-16 conv layers; the LUT
//! cost model (triple grouping + per-triple-group rescale, see
//! `nn::opcount`) reproduces the paper's absolute numbers.
//!
//! ```sh
//! cargo run --release --example opcount_report
//! ```

use lqr::eval::sweep;
use lqr::nn::opcount::{lut_ops, original_ops, LutCostModel};
use lqr::nn::Arch;

fn main() {
    sweep::table3().print();

    // Ablation: how the LUT grouping factor moves the counts.
    println!("LUT grouping ablation (AlexNet conv ops, millions):");
    let arch = Arch::alexnet_full();
    let o = original_ops(&arch);
    for group in [2usize, 3, 4] {
        let l = lut_ops(&arch, LutCostModel { group, combine: 3 });
        println!(
            "  group={group}: multiplies {}M ({:.1}x less), adds {}M ({:.1}x less)",
            l.multiplies / 1_000_000,
            o.multiplies as f64 / l.multiplies as f64,
            l.adds / 1_000_000,
            o.adds as f64 / l.adds as f64,
        );
    }
}
