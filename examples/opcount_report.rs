//! Table 3 — multiply/add counts, original vs 2-bit LUT scheme.
//!
//! Analytic counts over the *full* AlexNet and VGG-16 conv layers; the LUT
//! cost model (triple grouping + per-triple-group rescale, see
//! `nn::opcount`) reproduces the paper's absolute numbers.
//!
//! ```sh
//! cargo run --release --example opcount_report
//! ```

use lqr::eval::sweep;
use lqr::nn::opcount::{bitserial_ops, lut_ops, original_ops, LutCostModel};
use lqr::nn::Arch;

fn main() {
    sweep::table3().print();

    // Ablation: how the LUT grouping factor moves the counts.
    println!("LUT grouping ablation (AlexNet conv ops, millions):");
    let arch = Arch::alexnet_full();
    let o = original_ops(&arch);
    for group in [2usize, 3, 4] {
        let l = lut_ops(&arch, LutCostModel { group, combine: 3 });
        println!(
            "  group={group}: multiplies {}M ({:.1}x less), adds {}M ({:.1}x less)",
            l.multiplies / 1_000_000,
            o.multiplies as f64 / l.multiplies as f64,
            l.adds / 1_000_000,
            o.adds as f64 / l.adds as f64,
        );
    }

    // Bit-serial sweep: AND+popcount word ops scale with bits_a * bits_w,
    // so halving the width quarters the inner-loop work (vs the u8 panel
    // path, where every width <= 8 costs the same K MACs per output).
    println!("bit-serial word-op sweep (AlexNet conv, millions of 64-lane word ops):");
    for bits in [1u8, 2, 4] {
        let b = bitserial_ops(&arch, bits, bits);
        println!(
            "  {bits}-bit x {bits}-bit: {}M word ops ({:.1}x fewer than one MAC per element), {}M epilogue multiplies",
            b.adds / 1_000_000,
            o.adds as f64 / b.adds as f64,
            b.multiplies / 1_000_000,
        );
    }
}
