//! §V end to end: deploy-quantize to `.lqz`, reload *without* any f32
//! weights, and run 2-bit inference through the multiply-free LUT path —
//! the complete IoT deployment story of the paper.
//!
//! ```sh
//! cargo run --release --example lut_inference -- --limit 128
//! ```

use anyhow::Result;
use lqr::dataset::Dataset;
use lqr::eval::evaluate;
use lqr::nn::forward::Scheme;
use lqr::nn::{Arch, Engine, Precision};
use lqr::quant::lut::WeightLut;
use lqr::quant::serialize::write_lqz;
use lqr::quant::RegionSpec;
use lqr::util::cli::Args;

fn main() -> Result<()> {
    lqr::util::logging::init();
    let p = Args::new("lut_inference", "2-bit LUT deployment demo")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("limit", "128", "validation images")
        .flag("region", "9", "LQ region size")
        .parse_from(&std::env::args().skip(1).collect::<Vec<_>>())
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let artifacts = p.get("artifacts");
    let limit = p.get_usize("limit");
    let region = RegionSpec::Size(p.get_usize("region"));

    // 1. Build host: quantize the trained model offline -> .lqz.
    let build_engine =
        Engine::from_npz(Arch::minivgg(), format!("{artifacts}/weights_minivgg.npz"))?;
    let lqz_path = std::env::temp_dir().join("minivgg_deploy.lqz");
    write_lqz(&lqz_path, &build_engine.to_lqz_entries(8, region))?;
    let lqz_bytes = std::fs::metadata(&lqz_path)?.len();
    let npz_bytes = std::fs::metadata(format!("{artifacts}/weights_minivgg.npz"))?.len();
    println!(
        "deploy artifact: {} ({:.0} KB; f32 npz is {:.0} KB -> {:.1}x smaller)",
        lqz_path.display(),
        lqz_bytes as f64 / 1e3,
        npz_bytes as f64 / 1e3,
        npz_bytes as f64 / lqz_bytes as f64
    );

    // 2. Device: reload from .lqz only.
    let device_engine = Engine::from_lqz(Arch::minivgg(), &lqz_path)?;
    let ds = Dataset::load(format!("{artifacts}/data"), "val")?.take(limit);

    // 3. 2-bit inference, integer MAC path vs multiply-free LUT path.
    let mac = Precision::Quant { scheme: Scheme::Lq, bits_a: 2, bits_w: 8, region, lut: false };
    let lut = Precision::Quant { scheme: Scheme::Lq, bits_a: 2, bits_w: 8, region, lut: true };
    let acc_mac = evaluate(&device_engine, &ds, mac, 32, None);
    let acc_lut = evaluate(&device_engine, &ds, lut, 32, None);
    println!(
        "2-bit inference on {} images: MAC path top-1 {:.1}%  |  LUT path top-1 {:.1}%",
        acc_mac.n,
        acc_mac.top1 * 100.0,
        acc_lut.top1 * 100.0
    );
    assert_eq!(acc_mac.top1, acc_lut.top1, "LUT must be numerically identical");

    // 4. The table itself (paper Fig. 5): weight tables hold w*c per code.
    let qw: Vec<i32> = (0..9).map(|i| (i * 17 % 256) as i32).collect();
    let table = WeightLut::build(&qw, 2);
    let qa: Vec<u8> = vec![3, 0, 1, 2, 3, 1, 0, 2, 1];
    println!(
        "one 9-element region: table {} bytes, dot via lookups = {} (multiply-free)",
        table.bytes(),
        table.dot(&qa)
    );
    std::fs::remove_file(&lqz_path).ok();
    println!("OK — deployed 2-bit LUT inference matches the integer path exactly");
    Ok(())
}
