//! Quickstart: load an AOT artifact, classify one validation image.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! This exercises the whole three-layer stack once: the image comes from the
//! build-time dataset, the HLO artifact was lowered from the JAX model (L2)
//! containing the Pallas LQ kernels (L1), and the rust runtime (L3) compiles
//! and executes it via PJRT.

use anyhow::Result;
use lqr::dataset::Dataset;
use lqr::runtime::Session;

fn main() -> Result<()> {
    lqr::util::logging::init();
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // 1. Open a PJRT session over the artifacts directory.
    let mut session = Session::open(&artifacts)?;

    // 2. Compile the 8-bit local-quantization variant of MiniAlexNet
    //    (runtime activation quantization + eq. 7 GEMMs, lowered from Pallas).
    let runner = session.load("minialexnet_lq8_b1")?;

    // 3. Classify one validation image.
    let ds = Dataset::load(format!("{artifacts}/data"), "val")?;
    let image = ds.image(0);
    let logits = session.run(&runner, &image)?;
    let row = logits.row(0);
    let pred = row
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;

    println!("artifact : {}", runner.meta.name);
    println!("logits   : {:?}", &row[..8.min(row.len())]);
    println!("predicted: class {pred}   (label: {})", ds.labels[0]);
    assert_eq!(pred as i32, ds.labels[0], "quickstart misclassified image 0");
    println!("OK — 8-bit LQ artifact agrees with the label");
    Ok(())
}
