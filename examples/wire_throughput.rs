//! Wire hot-path benchmark: the zero-copy serving changes, measured.
//!
//! Three sections, no artifacts needed (synthetic weights + mock backend):
//!
//! 1. **Wire throughput** — a `NetServer` over a mock route at MiniAlexNet
//!    frame geometry (3x32x32 = 12 KiB payloads), driven closed-loop by
//!    1/2/4 pipelining clients. Records requests/sec, requests/sec/core and
//!    p50/p99 round latency. This path exercises the pooled frame buffers,
//!    the image-recycle ring and the gathered single-write replies.
//! 2. **Model-load latency** — a MiniAlexNet-sized npz synthesized in
//!    memory, loaded through the copy-free path (single read, parse from
//!    slice, move storage into tensors). Records archive bytes and load ms.
//! 3. **Panel sharing** — one shared engine pre-warmed at LQ-2: resident
//!    panel bytes for the shared cache vs what N private per-worker engines
//!    would hold. The N× saving is the shared-Engine tentpole, in bytes.
//!
//! Results land in `BENCH_wire.json` at the repo root. `--smoke` shrinks
//! the sweep for CI.
//!
//! ```sh
//! cargo run --release --example wire_throughput [-- --smoke]
//! ```

use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use lqr::coordinator::backend::{shared_native_factory, Backend, MockBackend};
use lqr::coordinator::net::{ImageSpec, NetClient, NetServer};
use lqr::coordinator::router::Router;
use lqr::coordinator::CoordinatorConfig;
use lqr::eval::TableFmt;
use lqr::nn::{Arch, Engine, Layer, Precision};
use lqr::tensor::{npz_bytes, NpzData, NpzEntry, Tensor};
use lqr::util::rng::Rng;
use lqr::util::stats::percentile;

/// MiniAlexNet frame geometry: what a real deployment ships per request.
const SPEC: ImageSpec = ImageSpec { c: 3, h: 32, w: 32 };

// -------------------------------------------------------- wire throughput --

struct WireRow {
    clients: usize,
    requests: usize,
    rps: f64,
    rps_per_core: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn wire_throughput(clients: usize, per_client: usize) -> Result<WireRow> {
    let mut r = Router::new();
    r.add_route(
        "mock",
        CoordinatorConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_capacity: 4096,
            ..Default::default()
        },
        Box::new(|| {
            Ok(Box::new(MockBackend {
                classes: 16,
                delay: Duration::ZERO,
                calls: Arc::new(AtomicU64::new(0)),
            }) as Box<dyn Backend>)
        }),
    )
    .unwrap();
    let server = NetServer::serve("127.0.0.1:0", Arc::new(r), SPEC)?;
    let addr = server.addr;

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|id| {
            std::thread::spawn(move || -> Result<Vec<f64>> {
                let mut c = NetClient::connect(addr)?;
                c.set_io_timeout(Some(Duration::from_secs(30)))?;
                let img = Tensor::filled(&[1, SPEC.c, SPEC.h, SPEC.w], 0.25 + id as f32 * 0.1);
                let mut lat_ms = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let t = Instant::now();
                    let (logits, _) = c.classify("mock", &img).map_err(anyhow::Error::from)?;
                    lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
                    assert_eq!(logits.len(), 16);
                }
                Ok(lat_ms)
            })
        })
        .collect();
    let mut lat_ms = Vec::with_capacity(clients * per_client);
    for h in handles {
        lat_ms.extend(h.join().expect("client thread panicked")?);
    }
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown();

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let total = clients * per_client;
    Ok(WireRow {
        clients,
        requests: total,
        rps: total as f64 / wall,
        rps_per_core: total as f64 / wall / cores as f64,
        p50_ms: percentile(&lat_ms, 0.50),
        p99_ms: percentile(&lat_ms, 0.99),
    })
}

// ------------------------------------------------------------- model load --

/// Synthesize a MiniAlexNet-shaped npz archive in memory (same member
/// names/shapes as `make artifacts` writes, random values).
fn synth_weights(arch: &Arch) -> Vec<u8> {
    let mut rng = Rng::new(0xBE9C);
    let mut entries = Vec::new();
    for l in &arch.layers {
        let (wshape, blen): (Vec<usize>, usize) = match *l {
            Layer::Conv { cin, cout, k, groups, .. } => (vec![cout, cin / groups, k, k], cout),
            Layer::Fc { cin, cout, .. } => (vec![cin, cout], cout),
        };
        let n: usize = wshape.iter().product();
        entries.push(NpzEntry {
            name: format!("{}.w", l.name()),
            shape: wshape,
            data: NpzData::F32(rng.normal_vec(n).iter().map(|v| v * 0.1).collect()),
        });
        entries.push(NpzEntry {
            name: format!("{}.b", l.name()),
            shape: vec![blen],
            data: NpzData::F32(rng.normal_vec(blen)),
        });
    }
    npz_bytes(&entries)
}

struct LoadResult {
    archive_bytes: usize,
    load_ms: f64,
    params: usize,
    engine: Engine,
}

fn model_load() -> Result<LoadResult> {
    let arch = Arch::minialexnet();
    let archive = synth_weights(&arch);
    let archive_bytes = archive.len();
    let path = std::env::temp_dir().join("lqr_wire_throughput_weights.npz");
    std::fs::write(&path, &archive)?;
    // Copy-free load: one file read, parse from slice, storage moved (not
    // cloned) into the engine's tensors.
    let t0 = Instant::now();
    let engine = Engine::from_npz(arch, &path)?;
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
    let _ = std::fs::remove_file(&path);
    let params = engine.arch.param_count();
    Ok(LoadResult { archive_bytes, load_ms, params, engine })
}

// ---------------------------------------------------------- panel sharing --

struct PanelResult {
    panels: usize,
    panel_bytes: usize,
    prewarm_ms: f64,
    workers: usize,
    shared_bytes: usize,
    unshared_bytes: usize,
}

fn panel_sharing(engine: Engine, workers: usize) -> PanelResult {
    let engine = Arc::new(engine);
    let precision = Precision::lq(2);
    let t0 = Instant::now();
    let (factory, warmed) = shared_native_factory(Arc::clone(&engine), precision);
    let prewarm_ms = t0.elapsed().as_secs_f64() * 1e3;
    // Build every worker's backend; all attach to the one warmed cache.
    let _backends: Vec<_> = (0..workers).map(|_| factory().unwrap()).collect();
    let stats = engine.panel_stats();
    assert_eq!(warmed, stats.panels, "pre-warm must account for every panel");
    PanelResult {
        panels: stats.panels,
        panel_bytes: stats.bytes,
        prewarm_ms,
        workers,
        shared_bytes: stats.bytes,
        // What N per-worker private engines would resident-hold: one full
        // panel set each (the pre-tentpole layout).
        unshared_bytes: stats.bytes * workers,
    }
}

// ------------------------------------------------------------------- json --

fn write_bench_json(
    rows: &[WireRow],
    load: &LoadResult,
    panels: &PanelResult,
    smoke: bool,
) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"wire_hot_path\",\n");
    s.push_str("  \"schema\": 1,\n");
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str(&format!(
        "  \"frame\": \"{}x{}x{} f32 ({} bytes payload)\",\n",
        SPEC.c,
        SPEC.h,
        SPEC.w,
        SPEC.c * SPEC.h * SPEC.w * 4
    ));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"clients\": {}, \"requests\": {}, \"rps\": {:.1}, \
             \"rps_per_core\": {:.1}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}}}{}\n",
            r.clients,
            r.requests,
            r.rps,
            r.rps_per_core,
            r.p50_ms,
            r.p99_ms,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"model_load\": {{\"model\": \"minialexnet\", \"archive_bytes\": {}, \
         \"load_ms\": {:.2}, \"params\": {}}},\n",
        load.archive_bytes, load.load_ms, load.params
    ));
    s.push_str(&format!(
        "  \"panels\": {{\"panels\": {}, \"panel_bytes\": {}, \"prewarm_ms\": {:.2}, \
         \"workers\": {}, \"shared_bytes\": {}, \"unshared_bytes\": {}}}\n",
        panels.panels,
        panels.panel_bytes,
        panels.prewarm_ms,
        panels.workers,
        panels.shared_bytes,
        panels.unshared_bytes
    ));
    s.push_str("}\n");
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_wire.json");
    std::fs::write(path, s)?;
    println!("wrote {path}");
    Ok(())
}

fn main() -> Result<()> {
    lqr::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (client_counts, per_client): (&[usize], usize) =
        if smoke { (&[1, 2], 300) } else { (&[1, 2, 4], 3000) };

    let mut t = TableFmt::new(
        "Wire hot path: pooled frame buffers + recycle ring + gathered replies (mock backend)",
        &["clients", "requests", "req/s", "req/s/core", "p50 ms", "p99 ms"],
    );
    let mut rows = Vec::new();
    for &clients in client_counts {
        let r = wire_throughput(clients, per_client)?;
        t.row(&[
            r.clients.to_string(),
            r.requests.to_string(),
            format!("{:.0}", r.rps),
            format!("{:.0}", r.rps_per_core),
            format!("{:.3}", r.p50_ms),
            format!("{:.3}", r.p99_ms),
        ]);
        rows.push(r);
    }
    t.print();

    let load = model_load()?;
    println!(
        "model load (copy-free): minialexnet {} params, {} archive bytes, {:.2} ms",
        load.params, load.archive_bytes, load.load_ms
    );

    let workers = if smoke { 2 } else { 4 };
    let panels = panel_sharing(load.engine, workers);
    println!(
        "panel sharing: {} panels, {} bytes resident shared across {} workers \
         (vs {} bytes unshared), pre-warm {:.2} ms",
        panels.panels, panels.shared_bytes, panels.workers, panels.unshared_bytes, panels.prewarm_ms
    );

    write_bench_json(&rows, &load, &panels, smoke)?;
    Ok(())
}
