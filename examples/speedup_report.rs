//! Fig. 8 — per-image runtime: 32-bit float baseline vs 8-bit fixed point.
//!
//! Two views, mirroring DESIGN.md's substitution:
//! - measured on this host: rust-native engine, f32 blocked GEMM vs the
//!   eq. 7 integer GEMM over the trained mini models;
//! - modelled for the paper's actual testbed: the Edison/Silvermont cost
//!   model over the full AlexNet / VGG-16 (including the paper's footnote
//!   that f32 VGG-16 does not fit the board's 1 GB).
//!
//! ```sh
//! cargo run --release --example speedup_report -- --images 20
//! ```

use anyhow::Result;
use lqr::eval::sweep;
use lqr::nn::opcount::weight_bytes;
use lqr::nn::Arch;
use lqr::util::cli::Args;

fn main() -> Result<()> {
    lqr::util::logging::init();
    let p = Args::new("speedup_report", "Fig. 8 runtime comparison")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("images", "20", "images measured per configuration")
        .parse_from(&std::env::args().skip(1).collect::<Vec<_>>())
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    sweep::fig8(p.get("artifacts"), p.get_usize("images"))?.print();

    // The paper's Fig. 8 footnote: f32 VGG-16 exceeds the Edison's 1 GB.
    let vgg = Arch::vgg16_full();
    println!(
        "VGG-16 weight footprint: f32 {:.0} MB (exceeds Edison's 1 GB with runtime overhead) \
         -> 8-bit {:.0} MB -> 2-bit {:.0} MB",
        weight_bytes(&vgg, 32) as f64 / 1e6,
        weight_bytes(&vgg, 8) as f64 / 1e6,
        weight_bytes(&vgg, 2) as f64 / 1e6,
    );
    Ok(())
}
