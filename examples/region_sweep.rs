//! Fig. 10 — accuracy vs local-region size at 2-bit input precision.
//!
//! The paper's §VI.F: shrinking the region below the kernel size recovers
//! most of the 2-bit accuracy loss (VGG-16 top-1 50.2% -> 68.3%). Here the
//! sweep runs on the trained MiniVGG with region sizes from kernel-sized
//! down to 3 elements.
//!
//! ```sh
//! cargo run --release --example region_sweep -- --regions 27,9,3 --limit 512
//! ```

use anyhow::Result;
use lqr::eval::sweep;
use lqr::util::cli::Args;

fn main() -> Result<()> {
    lqr::util::logging::init();
    let p = Args::new("region_sweep", "Fig. 10 region-size sweep (2-bit)")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("regions", "27,9,3", "region sizes (elements along K)")
        .flag("limit", "512", "validation images")
        .parse_from(&std::env::args().skip(1).collect::<Vec<_>>())
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    sweep::fig10(p.get("artifacts"), &p.get_usize_list("regions"), p.get_usize("limit"))?
        .print();
    Ok(())
}
