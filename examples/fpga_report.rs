//! Tables 4 + 5 — FPGA Matrix Multiplier: resources, timing, throughput,
//! power — plus a functional demonstration of the 4x4 CU array.
//!
//! The structural estimator regenerates the paper's synthesis table; the
//! cycle-level simulator then *runs* an actual quantized layer GEMM through
//! the ISC/PSC dataflow and cross-checks it against the software integer
//! GEMM, proving the modelled datapath computes the right numbers.
//!
//! ```sh
//! cargo run --release --example fpga_report
//! ```

use lqr::eval::sweep;
use lqr::platform::fpga::resource::CuConfig;
use lqr::platform::fpga::sim::simulate;
use lqr::quant::{quantize_matrix, RegionSpec};
use lqr::tensor::Tensor;
use lqr::util::rng::Rng;

fn main() {
    sweep::table45().print();

    // Functional demo: stream an 8-bit-weight x 2-bit-input GEMM (an
    // AlexNet-conv1-shaped panel) through the simulated array.
    let mut rng = Rng::new(3);
    let (m, k, n) = (8usize, 363usize, 12usize); // 363 = 11*11*3 (paper Fig. 7)
    let a = Tensor::new(&[m, k], rng.uniform_vec(m * k, 0.0, 1.0));
    let w = Tensor::new(&[n, k], rng.normal_vec(n * k));
    let aq = quantize_matrix(&a, 2, RegionSpec::PerRow);
    let wq = quantize_matrix(&w, 8, RegionSpec::PerRow);

    let a_codes: Vec<i32> = aq.codes.iter().map(|&c| c as i32).collect();
    // B matrix (k, n): transpose the per-row weight codes.
    let mut b_codes = vec![0i32; k * n];
    for j in 0..n {
        for p in 0..k {
            b_codes[p * n + j] = wq.codes[j * k + p] as i32;
        }
    }
    let cfg = CuConfig::Fixed { wp: 8, wi: 2 };
    let sim = simulate(cfg, &a_codes, &b_codes, m, k, n);

    // Cross-check against plain integer GEMM.
    let mut ok = true;
    for i in 0..m {
        for j in 0..n {
            let want: i64 = (0..k)
                .map(|p| a_codes[i * k + p] as i64 * b_codes[p * n + j] as i64)
                .sum();
            if sim.out[i * n + j] != want {
                ok = false;
            }
        }
    }
    println!("cycle-level 4x4 CU simulation of a {m}x{k}x{n} quantized GEMM ({}):", cfg.label());
    println!("  exact match vs software integer GEMM: {ok}");
    println!("  cycles: {}   MACs: {}   CU utilization: {:.1}%", sim.cycles, sim.macs, sim.utilization() * 100.0);
    assert!(ok, "systolic dataflow diverged from reference");

    // Whole-network mapping: per-image latency/energy of the full AlexNet /
    // VGG-16 on one Matrix Multiplier module per CU configuration.
    use lqr::nn::Arch;
    use lqr::platform::fpga::mapper::map_network;
    let mut t = lqr::eval::TableFmt::new(
        "Whole-network mapping on one 4x4 Matrix Multiplier (batch 1)",
        &["network", "config", "Mcycles", "latency @Fmax", "energy @200MHz", "CU util"],
    );
    for arch in [Arch::alexnet_full(), Arch::vgg16_full()] {
        for cfg in CuConfig::paper_rows() {
            let e = map_network(&arch, cfg);
            t.row(&[
                arch.name.into(),
                cfg.label(),
                format!("{:.0}", e.cycles as f64 / 1e6),
                format!("{:.0} ms", e.latency_ms),
                format!("{:.1} mJ", e.energy_mj),
                format!("{:.1}%", e.utilization * 100.0),
            ]);
        }
    }
    t.print();
}
